//! Packed-panel, register-tiled, multi-threaded GEMM kernel family — the
//! native engine's compute substrate.
//!
//! Three layouts cover every matmul in the model and its backward pass (all
//! matrices row-major f32, remainders of any size handled):
//!   * `nn`: C = A·B   (A [m,k], B [k,n]) — forward projections
//!   * `tn`: C = Aᵀ·B  (A [k,m], B [k,n]) — weight gradients
//!   * `nt`: C = A·Bᵀ  (A [m,k], B [n,k]) — activation gradients
//! each with an accumulating variant (C += …) so the backward pass fuses its
//! reductions instead of materializing temporaries, plus a fused
//! row-broadcast-bias epilogue for the cls/reg head and the SiLU·mul
//! elementwise pair for SwiGLU.
//!
//! ## Two execution paths, one summation contract
//!
//! Above `util::pack_min_mnk()` (m·n·k, `PALLAS_PACK_MIN`) B is packed ONCE
//! per call into cache-resident column panels of `NR` lanes (shared,
//! read-only, reused by every row block and every thread), and a 4×NR
//! register-tiled microkernel streams A over each panel. The accumulator
//! tile is a plain `[[f32; NR]; 4]` lane array — rustc/LLVM lower the fixed
//! NR-wide inner loops to SIMD on any target (an explicit `std::simd` or
//! intrinsics microkernel can slot in behind a feature flag later without
//! changing the contract). Below the threshold the direct blocked kernels
//! run (packing would cost more than it saves).
//!
//! Both paths implement the SAME per-element summation contract:
//!
//! > acc starts from C's prior value (0 when overwriting); the k products
//! > are added ONE AT A TIME in strictly ascending k order (no pairwise
//! > regrouping, no fused mul-add — rustc does not contract float ops);
//! > the bias epilogue, when present, is added once at the very end.
//!
//! so the packed and direct paths agree BIT FOR BIT (pinned by a property
//! test below), and the path choice — a pure throughput knob — can never
//! change a result.
//!
//! Parallelism: output rows are split into contiguous per-thread chunks
//! dispatched onto the process-wide persistent worker pool (`util::pool`;
//! `PALLAS_POOL=0` / `--pool 0` falls back to per-call scoped threads —
//! the legacy parity path). Each output element is owned by exactly one
//! chunk and its summation order is fixed by the contract above, so results
//! are bit-for-bit identical at ANY thread count and on EITHER dispatch
//! path — the property the golden pins, grad checks and thread-invariance
//! tests rely on. The worker count comes from `util::num_threads()`
//! (`PALLAS_NUM_THREADS`, parsed once) unless a caller pins it explicitly
//! (per-head attention work runs its inner GEMMs at a reduced count to
//! avoid oversubscription; on the pool, such nested dispatches run inline
//! on the issuing worker).
//!
//! The pack and chunk kernels below take explicit leading dimensions
//! (`lda`/`ldb`), so the batched strided sibling (`linalg::gemm_batched`)
//! reuses them verbatim over matrices carved out of larger buffers — same
//! per-element contract, therefore the same bits.

use crate::obs::{self, Counter, Span};
use crate::tensor::Tensor;
use crate::util::{self, pool};

/// Microkernel tile height: rows of C computed per register tile.
const MR: usize = 4;
/// Microkernel tile width: C lanes per packed B panel strip. 16 f32 = one
/// 64-byte line = 2×AVX2 / 1×AVX-512 / 4×NEON vectors.
pub const NR: usize = 16;
/// Depth (k) blocking for the DIRECT path: a KB×NB panel of B stays
/// L2-resident while it is streamed over a chunk's rows. Blocking never
/// regroups sums — the contract's ascending-k single-add order holds.
const KB: usize = 128;
/// Width (j) blocking for the direct path: C-row segments of NB f32 in L1.
const NB: usize = 256;

/// Anything readable as a row-major 2-D f32 matrix (rank-1 = a single row,
/// matching `Tensor::rows`). Lets the kernels consume owned activations and
/// borrowed parameter views interchangeably.
pub trait Mat {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    fn data(&self) -> &[f32];
}

/// Contiguous per-thread row ranges: first `m % t` chunks get one extra row.
pub(crate) fn split_rows(m: usize, threads: usize) -> Vec<(usize, usize)> {
    let t = threads.max(1).min(m.max(1));
    let (base, rem) = (m / t, m % t);
    let mut out = Vec::with_capacity(t);
    let mut i0 = 0;
    for c in 0..t {
        let len = base + usize::from(c < rem);
        out.push((i0, i0 + len));
        i0 += len;
    }
    out
}

/// Run `body(i0, i1, c_rows)` over disjoint row chunks of `c` in parallel.
/// Chunk boundaries depend only on (m, threads); each chunk's work is
/// self-contained, so any thread count computes identical bits.
pub(crate) fn par_rows<F>(c: &mut [f32], m: usize, n: usize, threads: usize, body: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(c.len(), m * n);
    let chunks = split_rows(m, threads);
    obs::add(Counter::ParChunks, chunks.len() as u64);
    if chunks.len() == 1 {
        body(0, m, c);
        return;
    }
    let base = pool::SendPtr(c.as_mut_ptr());
    pool::run(chunks.len(), &|ci| {
        let (i0, i1) = chunks[ci];
        // SAFETY: chunks are disjoint row ranges of `c`, so the slices
        // never alias, and `pool::run` returns only after every job
        // finished, so none outlives the borrow.
        let rows = unsafe { std::slice::from_raw_parts_mut(base.0.add(i0 * n), (i1 - i0) * n) };
        body(i0, i1, rows);
    });
}

/// Two-output variant of [`par_rows`]: splits `a` (rows of width `na`) and
/// `b` (rows of width `nb`) by the SAME row chunks — the rowwise sweeps
/// that produce a pair (rmsnorm's (y, 1/rms), SiLU·mul's (dg, du)) write
/// both outputs in one parallel pass with no stitching copies.
pub(crate) fn par_rows2<F>(
    a: &mut [f32],
    b: &mut [f32],
    m: usize,
    na: usize,
    nb: usize,
    threads: usize,
    body: F,
) where
    F: Fn(usize, usize, &mut [f32], &mut [f32]) + Sync,
{
    debug_assert_eq!(a.len(), m * na);
    debug_assert_eq!(b.len(), m * nb);
    let chunks = split_rows(m, threads);
    obs::add(Counter::ParChunks, chunks.len() as u64);
    if chunks.len() == 1 {
        body(0, m, a, b);
        return;
    }
    let base_a = pool::SendPtr(a.as_mut_ptr());
    let base_b = pool::SendPtr(b.as_mut_ptr());
    pool::run(chunks.len(), &|ci| {
        let (i0, i1) = chunks[ci];
        // SAFETY: disjoint row ranges of `a` and `b`; `pool::run` joins
        // before returning (see par_rows).
        let ca =
            unsafe { std::slice::from_raw_parts_mut(base_a.0.add(i0 * na), (i1 - i0) * na) };
        let cb =
            unsafe { std::slice::from_raw_parts_mut(base_b.0.add(i0 * nb), (i1 - i0) * nb) };
        body(i0, i1, ca, cb);
    });
}

// ---------------------------------------------------------------------------
// packed path: B panels + register-tiled microkernel
// ---------------------------------------------------------------------------

/// B packed into column-panel strips: strip `s` holds columns
/// `[s*NR, s*NR + NR)` as `k` consecutive NR-wide lanes (zero-padded past
/// `n`), so the microkernel's B loads are perfectly sequential. Packed once
/// per GEMM call and shared read-only across all row chunks and threads.
pub(crate) struct PackedB {
    k: usize,
    data: Vec<f32>,
}

impl PackedB {
    /// Bytes staged into the panel buffer (obs accounting only).
    pub(crate) fn bytes(&self) -> u64 {
        4 * self.data.len() as u64
    }
}

/// Pack B [k, n] with row stride `ldb` (the `nn`/`tn` operand; contiguous
/// callers pass `ldb = n`). Packing is a pure copy, so a strided source
/// packs to the identical panel bytes as its dense twin.
pub(crate) fn pack_b_nn(b: &[f32], k: usize, n: usize, ldb: usize) -> PackedB {
    let strips = n.div_ceil(NR);
    let mut data = vec![0.0f32; strips * k * NR];
    for s in 0..strips {
        let j0 = s * NR;
        let w = NR.min(n - j0);
        let base = s * k * NR;
        for kk in 0..k {
            data[base + kk * NR..base + kk * NR + w]
                .copy_from_slice(&b[kk * ldb + j0..kk * ldb + j0 + w]);
        }
    }
    PackedB { k, data }
}

/// Pack B [n, k] with row stride `ldb` as the transposed operand of `nt`
/// (effective B'[kk, j] = B[j, kk]); reads each B row once, contiguously.
/// Contiguous callers pass `ldb = k`.
pub(crate) fn pack_b_nt(b: &[f32], n: usize, k: usize, ldb: usize) -> PackedB {
    let strips = n.div_ceil(NR);
    let mut data = vec![0.0f32; strips * k * NR];
    for s in 0..strips {
        let j0 = s * NR;
        let w = NR.min(n - j0);
        let base = s * k * NR;
        for jr in 0..w {
            let brow = &b[(j0 + jr) * ldb..(j0 + jr) * ldb + k];
            for (kk, &bv) in brow.iter().enumerate() {
                data[base + kk * NR + jr] = bv;
            }
        }
    }
    PackedB { k, data }
}

/// The register-tiled microkernel: an R×NR accumulator tile swept over one
/// packed strip's full k range. A's element (row, kk) lives at
/// `a[row*ars + kk*aks]` (`nn`/`nt`: ars=k, aks=1; `tn`: ars=1, aks=m), so
/// one kernel serves every layout. The fixed-size lane loops lower to SIMD;
/// each of the R×NR accumulators follows the ascending-k single-add
/// contract, so grouping rows into tiles never changes bits.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn micro_tile<const R: usize>(
    c: &mut [f32],
    c0: usize,
    cs: usize,
    w: usize,
    a: &[f32],
    a0: usize,
    ars: usize,
    aks: usize,
    strip: &[f32],
    k: usize,
    acc: bool,
    bias: Option<(&[f32], usize)>,
) {
    let mut t = [[0.0f32; NR]; R];
    if acc {
        for r in 0..R {
            t[r][..w].copy_from_slice(&c[c0 + r * cs..c0 + r * cs + w]);
        }
    }
    for kk in 0..k {
        let bl = &strip[kk * NR..kk * NR + NR];
        for r in 0..R {
            let av = a[a0 + r * ars + kk * aks];
            let tr = &mut t[r];
            for j in 0..NR {
                tr[j] += av * bl[j];
            }
        }
    }
    match bias {
        Some((bv, j0)) => {
            for r in 0..R {
                let crow = &mut c[c0 + r * cs..c0 + r * cs + w];
                for j in 0..w {
                    crow[j] = t[r][j] + bv[j0 + j];
                }
            }
        }
        None => {
            for r in 0..R {
                c[c0 + r * cs..c0 + r * cs + w].copy_from_slice(&t[r][..w]);
            }
        }
    }
}

/// One thread's row chunk of the packed path: for each strip (kept hot in
/// cache) sweep the chunk's rows in MR-high tiles. Tile grouping starts at
/// the chunk base, but per-row accumulation order is identical whatever the
/// grouping, so chunk boundaries (= thread count) never change bits. `a` is
/// addressed as `a[(i0 + li) * ars + kk * aks]`, so strided A operands plug
/// in by passing their row stride as `ars` (`nn`/`nt`) or `aks` (`tn`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn packed_chunk(
    c_rows: &mut [f32],
    i0: usize,
    n: usize,
    a: &[f32],
    ars: usize,
    aks: usize,
    pb: &PackedB,
    acc: bool,
    bias: Option<&[f32]>,
) {
    if n == 0 {
        return;
    }
    let k = pb.k;
    let rows = c_rows.len() / n;
    let strips = n.div_ceil(NR);
    for s in 0..strips {
        let strip = &pb.data[s * k * NR..(s + 1) * k * NR];
        let j0 = s * NR;
        let w = NR.min(n - j0);
        let b = bias.map(|bv| (bv, j0));
        let mut li = 0;
        while li + MR <= rows {
            let (c0, a0) = (li * n + j0, (i0 + li) * ars);
            micro_tile::<MR>(c_rows, c0, n, w, a, a0, ars, aks, strip, k, acc, b);
            li += MR;
        }
        let (c0, a0) = (li * n + j0, (i0 + li) * ars);
        match rows - li {
            3 => micro_tile::<3>(c_rows, c0, n, w, a, a0, ars, aks, strip, k, acc, b),
            2 => micro_tile::<2>(c_rows, c0, n, w, a, a0, ars, aks, strip, k, acc, b),
            1 => micro_tile::<1>(c_rows, c0, n, w, a, a0, ars, aks, strip, k, acc, b),
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------------
// direct path: serial chunk kernels (same per-element order as the packed
// path — ascending k, one add per product)
// ---------------------------------------------------------------------------

/// nn rows [i0, i0+rows): c_rows += A[i0.., :] · B. `a` is the FULL A
/// [m,k] with row stride `lda`; `b` is B [k,n] with row stride `ldb`
/// (contiguous callers pass `lda = k`, `ldb = n`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn nn_chunk(
    c_rows: &mut [f32],
    a: &[f32],
    b: &[f32],
    i0: usize,
    k: usize,
    n: usize,
    lda: usize,
    ldb: usize,
) {
    let rows = if n == 0 { 0 } else { c_rows.len() / n };
    for jb in (0..n).step_by(NB) {
        let je = (jb + NB).min(n);
        let w = je - jb;
        for kb in (0..k).step_by(KB) {
            let ke = (kb + KB).min(k);
            for li in 0..rows {
                let arow = &a[(i0 + li) * lda..(i0 + li) * lda + k];
                let crow = &mut c_rows[li * n + jb..li * n + je];
                let mut kk = kb;
                // 4-deep k-unroll: one pass over the C segment per 4 B rows.
                // Four SEPARATE adds per element keep the contract's
                // ascending-k single-add order (no pairwise regrouping).
                while kk + 4 <= ke {
                    let (a0, a1, a2, a3) = (arow[kk], arow[kk + 1], arow[kk + 2], arow[kk + 3]);
                    let b0 = &b[kk * ldb + jb..kk * ldb + jb + w];
                    let b1 = &b[(kk + 1) * ldb + jb..(kk + 1) * ldb + jb + w];
                    let b2 = &b[(kk + 2) * ldb + jb..(kk + 2) * ldb + jb + w];
                    let b3 = &b[(kk + 3) * ldb + jb..(kk + 3) * ldb + jb + w];
                    for (j, cv) in crow.iter_mut().enumerate() {
                        *cv += a0 * b0[j];
                        *cv += a1 * b1[j];
                        *cv += a2 * b2[j];
                        *cv += a3 * b3[j];
                    }
                    kk += 4;
                }
                while kk < ke {
                    let av = arow[kk];
                    let brow = &b[kk * ldb + jb..kk * ldb + je];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                    kk += 1;
                }
            }
        }
    }
}

/// tn rows [i0, i0+rows): c_rows += Aᵀ[i0.., :] · B for A [k,m] (row stride
/// `lda`), B [k,n] (row stride `ldb`); contiguous callers pass `lda = m`,
/// `ldb = n`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn tn_chunk(
    c_rows: &mut [f32],
    a: &[f32],
    b: &[f32],
    i0: usize,
    k: usize,
    m: usize,
    n: usize,
    lda: usize,
    ldb: usize,
) {
    let rows = if n == 0 { 0 } else { c_rows.len() / n };
    for jb in (0..n).step_by(NB) {
        let je = (jb + NB).min(n);
        for kb in (0..k).step_by(KB) {
            let ke = (kb + KB).min(k);
            for kk in kb..ke {
                let arow = &a[kk * lda..kk * lda + m];
                let brow = &b[kk * ldb + jb..kk * ldb + je];
                for li in 0..rows {
                    let av = arow[i0 + li];
                    let crow = &mut c_rows[li * n + jb..li * n + je];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
        }
    }
}

/// nt rows [i0, i0+rows): c_rows ⊕= A[i0.., :] · Bᵀ for A [m,k] (row stride
/// `lda`), B [n,k] (row stride `ldb`); contiguous callers pass `lda = k`,
/// `ldb = k`. Four independent dot accumulators per A row amortize the A
/// loads; each accumulator starts from C's prior value (contract) and sums
/// ascending k.
#[allow(clippy::too_many_arguments)]
pub(crate) fn nt_chunk(
    c_rows: &mut [f32],
    a: &[f32],
    b: &[f32],
    i0: usize,
    k: usize,
    n: usize,
    acc: bool,
    lda: usize,
    ldb: usize,
) {
    let rows = if n == 0 { 0 } else { c_rows.len() / n };
    for li in 0..rows {
        let arow = &a[(i0 + li) * lda..(i0 + li) * lda + k];
        let crow = &mut c_rows[li * n..(li + 1) * n];
        let mut j = 0;
        while j + 4 <= n {
            let b0 = &b[j * ldb..j * ldb + k];
            let b1 = &b[(j + 1) * ldb..(j + 1) * ldb + k];
            let b2 = &b[(j + 2) * ldb..(j + 2) * ldb + k];
            let b3 = &b[(j + 3) * ldb..(j + 3) * ldb + k];
            let (mut s0, mut s1, mut s2, mut s3) = if acc {
                (crow[j], crow[j + 1], crow[j + 2], crow[j + 3])
            } else {
                (0.0f32, 0.0f32, 0.0f32, 0.0f32)
            };
            for (kk, &av) in arow.iter().enumerate() {
                s0 += av * b0[kk];
                s1 += av * b1[kk];
                s2 += av * b2[kk];
                s3 += av * b3[kk];
            }
            crow[j] = s0;
            crow[j + 1] = s1;
            crow[j + 2] = s2;
            crow[j + 3] = s3;
            j += 4;
        }
        while j < n {
            let brow = &b[j * ldb..j * ldb + k];
            let mut s = if acc { crow[j] } else { 0.0f32 };
            for (&av, &bv) in arow.iter().zip(brow) {
                s += av * bv;
            }
            crow[j] = s;
            j += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// raw slice API (explicit thread count — tests and nested callers pin it)
// ---------------------------------------------------------------------------

fn gemm_threads(m: usize, k: usize, n: usize, threads: usize) -> usize {
    if m.saturating_mul(n).saturating_mul(k) < util::par_min_mnk() {
        1
    } else {
        threads
    }
}

/// Packed-path predicate: depends only on the problem shape and the (env /
/// `set_pack_min`) knob — never on the thread count — so the chosen path is
/// deterministic per call site. Both paths agree bitwise regardless. The
/// batched layer applies the same predicate to its per-element shape, so
/// one knob governs both call families.
pub(crate) fn use_packed(m: usize, k: usize, n: usize) -> bool {
    k > 0 && n > 0 && m.saturating_mul(n).saturating_mul(k) >= util::pack_min_mnk()
}

/// Open the per-call GEMM span and bump the path/FLOP counters. Purely
/// observational (inert unless `--trace`): reads clocks and bumps atomics,
/// never branches the math.
fn gemm_probe(m: usize, k: usize, n: usize, packed: bool) -> obs::SpanGuard {
    obs::add(if packed { Counter::GemmPackedCalls } else { Counter::GemmDirectCalls }, 1);
    obs::add(Counter::GemmFlops, 2 * (m as u64) * (k as u64) * (n as u64));
    obs::span(if packed { Span::GemmPacked } else { Span::GemmDirect })
}

/// Time a B-pack under the [`Span::GemmPack`] span and count staged bytes.
fn pack_probe(pack: impl FnOnce() -> PackedB) -> PackedB {
    let pb = {
        let _pk = obs::span(Span::GemmPack);
        pack()
    };
    obs::add(Counter::PackBytes, pb.bytes());
    pb
}

#[allow(clippy::too_many_arguments)]
fn gemm_nn_impl(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    acc: bool,
    threads: usize,
    packed: bool,
) {
    let threads = gemm_threads(m, k, n, threads);
    let _sp = gemm_probe(m, k, n, packed);
    if packed {
        let pb = pack_probe(|| pack_b_nn(b, k, n, n));
        par_rows(c, m, n, threads, |i0, _i1, rows| {
            packed_chunk(rows, i0, n, a, k, 1, &pb, acc, None);
        });
    } else {
        par_rows(c, m, n, threads, |i0, _i1, rows| {
            if !acc {
                rows.fill(0.0);
            }
            nn_chunk(rows, a, b, i0, k, n, k, n);
        });
    }
}

#[allow(clippy::too_many_arguments)]
fn gemm_tn_impl(
    k: usize,
    m: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    acc: bool,
    threads: usize,
    packed: bool,
) {
    let threads = gemm_threads(m, k, n, threads);
    let _sp = gemm_probe(m, k, n, packed);
    if packed {
        let pb = pack_probe(|| pack_b_nn(b, k, n, n));
        par_rows(c, m, n, threads, |i0, _i1, rows| {
            packed_chunk(rows, i0, n, a, 1, m, &pb, acc, None);
        });
    } else {
        par_rows(c, m, n, threads, |i0, _i1, rows| {
            if !acc {
                rows.fill(0.0);
            }
            tn_chunk(rows, a, b, i0, k, m, n, m, n);
        });
    }
}

#[allow(clippy::too_many_arguments)]
fn gemm_nt_impl(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    acc: bool,
    threads: usize,
    packed: bool,
) {
    let threads = gemm_threads(m, k, n, threads);
    let _sp = gemm_probe(m, k, n, packed);
    if packed {
        let pb = pack_probe(|| pack_b_nt(b, n, k, k));
        par_rows(c, m, n, threads, |i0, _i1, rows| {
            packed_chunk(rows, i0, n, a, k, 1, &pb, acc, None);
        });
    } else {
        par_rows(c, m, n, threads, |i0, _i1, rows| {
            nt_chunk(rows, a, b, i0, k, n, acc, k, k);
        });
    }
}

/// c ⊕= A·B. `acc=false` overwrites, `acc=true` accumulates.
#[allow(clippy::too_many_arguments)]
pub fn gemm_nn(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    acc: bool,
    threads: usize,
) {
    assert_eq!(a.len(), m * k, "gemm_nn: a len");
    assert_eq!(b.len(), k * n, "gemm_nn: b len");
    assert_eq!(c.len(), m * n, "gemm_nn: c len");
    gemm_nn_impl(m, k, n, a, b, c, acc, threads, use_packed(m, k, n));
}

/// c ⊕= Aᵀ·B for A [k,m], B [k,n].
#[allow(clippy::too_many_arguments)]
pub fn gemm_tn(
    k: usize,
    m: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    acc: bool,
    threads: usize,
) {
    assert_eq!(a.len(), k * m, "gemm_tn: a len");
    assert_eq!(b.len(), k * n, "gemm_tn: b len");
    assert_eq!(c.len(), m * n, "gemm_tn: c len");
    gemm_tn_impl(k, m, n, a, b, c, acc, threads, use_packed(m, k, n));
}

/// c ⊕= A·Bᵀ for A [m,k], B [n,k].
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    acc: bool,
    threads: usize,
) {
    assert_eq!(a.len(), m * k, "gemm_nt: a len");
    assert_eq!(b.len(), n * k, "gemm_nt: b len");
    assert_eq!(c.len(), m * n, "gemm_nt: c len");
    gemm_nt_impl(m, k, n, a, b, c, acc, threads, use_packed(m, k, n));
}

// ---------------------------------------------------------------------------
// Mat-level API (thread count from the shared util knob)
// ---------------------------------------------------------------------------

fn dims_nn(a: &dyn Mat, b: &dyn Mat) -> (usize, usize, usize) {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    (m, k, n)
}

/// C = A·B at an explicit thread count (reduced inside already-parallel
/// regions).
pub fn matmul_threads<A: Mat + ?Sized, B: Mat + ?Sized>(a: &A, b: &B, threads: usize) -> Tensor {
    let (m, k, n) = dims_nn(a, b);
    let mut c = Tensor::zeros(&[m, n]);
    gemm_nn(m, k, n, a.data(), b.data(), &mut c.data, false, threads);
    c
}

/// C = A·B.
pub fn matmul<A: Mat + ?Sized, B: Mat + ?Sized>(a: &A, b: &B) -> Tensor {
    matmul_threads(a, b, util::num_threads())
}

/// C = Aᵀ·B at an explicit thread count.
pub fn matmul_tn_threads<A: Mat + ?Sized, B: Mat + ?Sized>(a: &A, b: &B, threads: usize) -> Tensor {
    let (k, m) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_tn inner dims {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    gemm_tn(k, m, n, a.data(), b.data(), &mut c.data, false, threads);
    c
}

/// C = Aᵀ·B.
pub fn matmul_tn<A: Mat + ?Sized, B: Mat + ?Sized>(a: &A, b: &B) -> Tensor {
    matmul_tn_threads(a, b, util::num_threads())
}

/// C = A·Bᵀ at an explicit thread count.
pub fn matmul_nt_threads<A: Mat + ?Sized, B: Mat + ?Sized>(a: &A, b: &B, threads: usize) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_nt inner dims {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    gemm_nt(m, k, n, a.data(), b.data(), &mut c.data, false, threads);
    c
}

/// C = A·Bᵀ.
pub fn matmul_nt<A: Mat + ?Sized, B: Mat + ?Sized>(a: &A, b: &B) -> Tensor {
    matmul_nt_threads(a, b, util::num_threads())
}

/// dst += Aᵀ·B, accumulating straight into a raw gradient buffer (the
/// backward pass's weight-gradient sink — no temporary + add pass).
pub fn matmul_tn_acc<A: Mat + ?Sized, B: Mat + ?Sized>(dst: &mut [f32], a: &A, b: &B) {
    let (k, m) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_tn_acc inner dims {k} vs {k2}");
    gemm_tn(k, m, n, a.data(), b.data(), dst, true, util::num_threads());
}

/// c += A·Bᵀ (fused accumulation for dx-style sums of products).
pub fn matmul_nt_acc<A: Mat + ?Sized, B: Mat + ?Sized>(c: &mut Tensor, a: &A, b: &B) {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_nt_acc inner dims {k} vs {k2}");
    assert_eq!(c.shape, vec![m, n], "matmul_nt_acc: c shape");
    gemm_nt(m, k, n, a.data(), b.data(), &mut c.data, true, util::num_threads());
}

fn matmul_bias_impl(a: &dyn Mat, b: &dyn Mat, bias: &[f32], packed: bool) -> Tensor {
    let (m, k, n) = dims_nn(a, b);
    assert_eq!(bias.len(), n, "matmul_bias: bias len");
    let mut c = Tensor::zeros(&[m, n]);
    let threads = gemm_threads(m, k, n, util::num_threads());
    let _sp = gemm_probe(m, k, n, packed);
    let (ad, bd) = (a.data(), b.data());
    if packed {
        let pb = pack_probe(|| pack_b_nn(bd, k, n, n));
        par_rows(&mut c.data, m, n, threads, |i0, _i1, rows| {
            packed_chunk(rows, i0, n, ad, k, 1, &pb, false, Some(bias));
        });
    } else {
        par_rows(&mut c.data, m, n, threads, |i0, i1, rows| {
            nn_chunk(rows, ad, bd, i0, k, n, k, n);
            for li in 0..(i1 - i0) {
                let crow = &mut rows[li * n..(li + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(bias) {
                    *cv += bv;
                }
            }
        });
    }
    c
}

/// C = A·B + bias (bias broadcast over rows) — the cls/reg head forward,
/// fused into the same parallel pass as the GEMM (the packed path adds the
/// bias in the microkernel's write-back).
pub fn matmul_bias<A: Mat + ?Sized, B: Mat + ?Sized>(a: &A, b: &B, bias: &[f32]) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    matmul_bias_impl(a, b, bias, use_packed(m, k, n))
}

// ---------------------------------------------------------------------------
// fused activation kernels (SwiGLU)
// ---------------------------------------------------------------------------

/// prod = silu(g) ⊙ u, elementwise over equal-shape tensors.
pub fn silu_mul(g: &Tensor, u: &Tensor) -> Tensor {
    assert_eq!(g.shape, u.shape, "silu_mul shape");
    let mut prod = Tensor::zeros(&g.shape);
    let threads = if g.numel() < util::par_min_elems() { 1 } else { util::num_threads() };
    let (gd, ud) = (&g.data, &u.data);
    par_rows(&mut prod.data, g.numel(), 1, threads, |i0, i1, out| {
        for (li, pv) in out.iter_mut().enumerate() {
            let gv = gd[i0 + li];
            let sg = 1.0 / (1.0 + (-gv).exp());
            *pv = gv * sg * ud[i0 + li];
        }
        let _ = i1;
    });
    prod
}

/// VJP of `silu_mul`: given dprod and the cached (g, u), returns (dg, du).
/// One parallel rowwise sweep writes both outputs in place (`par_rows2`);
/// each element's math is self-contained, so the result is
/// thread-count-invariant.
pub fn silu_mul_vjp(dprod: &Tensor, g: &Tensor, u: &Tensor) -> (Tensor, Tensor) {
    assert_eq!(dprod.shape, g.shape, "silu_mul_vjp shape");
    assert_eq!(g.shape, u.shape, "silu_mul_vjp shape");
    let nlen = g.numel();
    let threads = if nlen < util::par_min_elems() { 1 } else { util::num_threads() };
    let mut dg = Tensor::zeros(&g.shape);
    let mut du = Tensor::zeros(&g.shape);
    let (dpd, gd, ud) = (&dprod.data, &g.data, &u.data);
    par_rows2(&mut dg.data, &mut du.data, nlen, 1, 1, threads, |i0, i1, dgc, duc| {
        for li in 0..(i1 - i0) {
            let gv = gd[i0 + li];
            let sg = 1.0 / (1.0 + (-gv).exp());
            let sil = gv * sg;
            let dp = dpd[i0 + li];
            duc[li] = dp * sil;
            // d silu(g)/dg = sg * (1 + g * (1 - sg))
            dgc[li] = dp * ud[i0 + li] * (sg * (1.0 + gv * (1.0 - sg)));
        }
    });
    (dg, du)
}

/// Deterministic parallel map over `0..n`: results in index order. Work item
/// `i` always computes the same bits regardless of which thread runs it, so
/// the output is thread-count-invariant. Items should pin their own inner
/// kernels to a reduced thread count to avoid oversubscription (on the
/// pool, an item's nested dispatches run inline on its worker anyway).
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = util::num_threads().min(n.max(1));
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let chunks = split_rows(n, threads);
    let base = pool::SendPtr(out.as_mut_ptr());
    pool::run(chunks.len(), &|ci| {
        let (i0, i1) = chunks[ci];
        // SAFETY: disjoint slot ranges of `out` (T: Send moves each
        // result across the thread boundary); `pool::run` joins before
        // returning (see par_rows).
        let slots = unsafe { std::slice::from_raw_parts_mut(base.0.add(i0), i1 - i0) };
        for (li, slot) in slots.iter_mut().enumerate() {
            *slot = Some(f(i0 + li));
        }
    });
    out.into_iter().map(|x| x.expect("parallel_map slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_vec(n: usize, rng: &mut Pcg64) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    fn naive_nn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f64; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += (a[i * k + kk] as f64) * (b[kk * n + j] as f64);
                }
            }
        }
        c.into_iter().map(|x| x as f32).collect()
    }

    fn assert_close(got: &[f32], want: &[f32], tol: f32) {
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            assert!((g - w).abs() <= tol * (1.0 + w.abs()), "elem {i}: {g} vs {w}");
        }
    }

    fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
        let mut t = vec![0.0f32; rows * cols];
        for i in 0..rows {
            for j in 0..cols {
                t[j * rows + i] = src[i * cols + j];
            }
        }
        t
    }

    #[test]
    fn all_layouts_match_naive_incl_remainders_on_both_paths() {
        let mut rng = Pcg64::new(1);
        // dims straddle the NR strips, MR tiles, KB/NB blocks and the 4-way
        // unroll remainders
        let dims = [(1, 1, 1), (3, 5, 2), (7, 17, 9), (13, 129, 31), (33, 260, 257), (5, 1, 4)];
        for &(m, k, n) in &dims {
            let a = rand_vec(m * k, &mut rng);
            let b = rand_vec(k * n, &mut rng);
            let want = naive_nn(m, k, n, &a, &b);
            let at = transpose(&a, m, k); // [k, m]
            let bt = transpose(&b, k, n); // [n, k]
            for packed in [false, true] {
                let mut c = vec![0.0f32; m * n];
                gemm_nn_impl(m, k, n, &a, &b, &mut c, false, 2, packed);
                assert_close(&c, &want, 1e-4);
                let mut c2 = vec![0.0f32; m * n];
                gemm_tn_impl(k, m, n, &at, &b, &mut c2, false, 3, packed);
                assert_close(&c2, &want, 1e-4);
                let mut c3 = vec![0.0f32; m * n];
                gemm_nt_impl(m, k, n, &a, &bt, &mut c3, false, 2, packed);
                assert_close(&c3, &want, 1e-4);
            }
        }
    }

    /// The contract that makes the packing threshold a pure throughput knob:
    /// the packed microkernel and the direct kernels produce IDENTICAL BITS
    /// for every layout, accumulate mode and thread count, bias included.
    #[test]
    fn packed_and_direct_paths_agree_bitwise() {
        let mut rng = Pcg64::new(0xACED);
        for trial in 0..40 {
            let m = 1 + rng.below(40);
            let k = 1 + rng.below(70);
            let n = 1 + rng.below(50);
            let a = rand_vec(m * k, &mut rng);
            let b = rand_vec(k * n, &mut rng);
            let at = transpose(&a, m, k);
            let bt = transpose(&b, k, n);
            let init = rand_vec(m * n, &mut rng);
            let bias = rand_vec(n, &mut rng);
            for acc in [false, true] {
                for &threads in &[1usize, 3] {
                    let mut dir = init.clone();
                    let mut pac = init.clone();
                    gemm_nn_impl(m, k, n, &a, &b, &mut dir, acc, threads, false);
                    gemm_nn_impl(m, k, n, &a, &b, &mut pac, acc, threads, true);
                    assert_eq!(dir, pac, "nn trial {trial} acc={acc} t={threads}");

                    let mut dir = init.clone();
                    let mut pac = init.clone();
                    gemm_tn_impl(k, m, n, &at, &b, &mut dir, acc, threads, false);
                    gemm_tn_impl(k, m, n, &at, &b, &mut pac, acc, threads, true);
                    assert_eq!(dir, pac, "tn trial {trial} acc={acc} t={threads}");

                    let mut dir = init.clone();
                    let mut pac = init.clone();
                    gemm_nt_impl(m, k, n, &a, &bt, &mut dir, acc, threads, false);
                    gemm_nt_impl(m, k, n, &a, &bt, &mut pac, acc, threads, true);
                    assert_eq!(dir, pac, "nt trial {trial} acc={acc} t={threads}");
                }
            }
            // fused bias epilogue (always overwriting)
            let av = Tensor::from_vec(&[m, k], a.clone()).unwrap();
            let bv = Tensor::from_vec(&[k, n], b.clone()).unwrap();
            let dir = matmul_bias_impl(&av, &bv, &bias, false);
            let pac = matmul_bias_impl(&av, &bv, &bias, true);
            assert_eq!(dir.data, pac.data, "bias trial {trial}");
        }
    }

    #[test]
    fn results_are_bitwise_identical_at_any_thread_count() {
        let mut rng = Pcg64::new(2);
        let (m, k, n) = (37, 141, 53);
        let a = rand_vec(m * k, &mut rng);
        let b = rand_vec(k * n, &mut rng);
        let bt = transpose(&b, k, n);
        for packed in [false, true] {
            let mut base_nn = vec![0.0f32; m * n];
            let mut base_nt = vec![0.0f32; m * n];
            gemm_nn_impl(m, k, n, &a, &b, &mut base_nn, false, 1, packed);
            gemm_nt_impl(m, k, n, &a, &bt, &mut base_nt, false, 1, packed);
            for threads in [2, 3, 4, 7, 64] {
                let mut c = vec![0.0f32; m * n];
                gemm_nn_impl(m, k, n, &a, &b, &mut c, false, threads, packed);
                assert_eq!(c, base_nn, "nn differs at {threads} threads (packed={packed})");
                let mut c2 = vec![0.0f32; m * n];
                gemm_nt_impl(m, k, n, &a, &bt, &mut c2, false, threads, packed);
                assert_eq!(c2, base_nt, "nt differs at {threads} threads (packed={packed})");
            }
        }
    }

    #[test]
    fn accumulating_variants_add_on_top() {
        let mut rng = Pcg64::new(3);
        let (m, k, n) = (6, 10, 8);
        let a = rand_vec(m * k, &mut rng);
        let b = rand_vec(k * n, &mut rng);
        let want = naive_nn(m, k, n, &a, &b);
        let shifted: Vec<f32> = want.iter().map(|w| w + 1.0).collect();
        for packed in [false, true] {
            let mut c = vec![1.0f32; m * n];
            gemm_nn_impl(m, k, n, &a, &b, &mut c, true, 2, packed);
            assert_close(&c, &shifted, 1e-4);
        }
    }

    #[test]
    fn matmul_bias_fuses_row_broadcast() {
        let mut rng = Pcg64::new(4);
        let a = Tensor::from_vec(&[3, 4], rand_vec(12, &mut rng)).unwrap();
        let b = Tensor::from_vec(&[4, 5], rand_vec(20, &mut rng)).unwrap();
        let bias = rand_vec(5, &mut rng);
        let got = matmul_bias(&a, &b, &bias);
        let plain = matmul(&a, &b);
        for i in 0..3 {
            for j in 0..5 {
                let want = plain.at(i, j) + bias[j];
                assert!((got.at(i, j) - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn silu_mul_and_vjp_match_reference_and_finite_difference() {
        let mut rng = Pcg64::new(5);
        let g = Tensor::from_vec(&[2, 9], rand_vec(18, &mut rng)).unwrap();
        let u = Tensor::from_vec(&[2, 9], rand_vec(18, &mut rng)).unwrap();
        let prod = silu_mul(&g, &u);
        for i in 0..g.numel() {
            let gv = g.data[i];
            let want = gv / (1.0 + (-gv).exp()) * u.data[i];
            assert!((prod.data[i] - want).abs() < 1e-6);
        }
        // scalar objective sum(prod): dprod = 1
        let ones = Tensor::from_vec(&[2, 9], vec![1.0; 18]).unwrap();
        let (dg, du) = silu_mul_vjp(&ones, &g, &u);
        let f = |g: &Tensor, u: &Tensor| -> f64 {
            silu_mul(g, u).data.iter().map(|&x| x as f64).sum()
        };
        let eps = 1e-3f32;
        for &i in &[0usize, 7, 17] {
            let mut gp = g.clone();
            gp.data[i] += eps;
            let mut gm = g.clone();
            gm.data[i] -= eps;
            let fd = (f(&gp, &u) - f(&gm, &u)) / (2.0 * eps as f64);
            assert!((fd - dg.data[i] as f64).abs() < 1e-2 * (1.0 + fd.abs()), "dg[{i}]");
            let mut up = u.clone();
            up.data[i] += eps;
            let mut um = u.clone();
            um.data[i] -= eps;
            let fdu = (f(&g, &up) - f(&g, &um)) / (2.0 * eps as f64);
            assert!((fdu - du.data[i] as f64).abs() < 1e-2 * (1.0 + fdu.abs()), "du[{i}]");
        }
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(257, |i| i * i);
        assert_eq!(out.len(), 257);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
        assert!(parallel_map(0, |i| i).is_empty());
    }

    #[test]
    fn par_rows2_splits_both_outputs_by_the_same_rows() {
        let m = 103;
        let (na, nb) = (3, 1);
        let mut a = vec![0.0f32; m * na];
        let mut b = vec![0.0f32; m * nb];
        par_rows2(&mut a, &mut b, m, na, nb, 4, |i0, i1, ac, bc| {
            for li in 0..(i1 - i0) {
                for j in 0..na {
                    ac[li * na + j] = (i0 + li) as f32 + j as f32 / 10.0;
                }
                bc[li] = (i0 + li) as f32;
            }
        });
        for i in 0..m {
            assert_eq!(b[i], i as f32);
            for j in 0..na {
                assert_eq!(a[i * na + j], i as f32 + j as f32 / 10.0);
            }
        }
    }
}
