//! Batched strided GEMM over a leading batch dimension — the attention
//! path's kernel substrate.
//!
//! Computes `C_i ⊕= op(A_i) · op(B_i)` for `i = 0..batch` in ONE call:
//! `nn` (C = A·B), `tn` (C = Aᵀ·B) and `nt` (C = A·Bᵀ), each with an
//! accumulating variant. A and B are [`BatchView`]s — equally-shaped
//! matrices carved out of larger buffers by per-matrix offsets and a row
//! stride — so the per-(batch·head) operands of attention (column slices of
//! an interleaved [b*t, h*dh] activation) feed the kernels with ZERO
//! gather copies. C is written dense: `batch` matrices packed back to back.
//!
//! ## Why batched beats the per-head loop
//!
//! A loop of b·h tiny GEMMs either runs each call serially (starving the
//! machine) or fans the heads out and hands each call a sliver of the
//! thread budget (`threads / (b·h)`) — both leave row-tile parallelism on
//! the table. Here the scheduling grid is the WHOLE (batch × row) space:
//! `par_rows` splits `batch·m` output rows into contiguous per-thread
//! chunks (a chunk may span several batch elements), so every thread
//! stays busy regardless of how b·h compares to the worker count. The
//! chunks run on the persistent worker pool (`util::pool`) through
//! `par_rows`/`parallel_map`, so batched calls inherit the pooled (or
//! `PALLAS_POOL=0` scoped) dispatch path automatically.
//!
//! ## Same contract, same bits
//!
//! Every per-element summation runs through the SAME kernels as the
//! non-batched layer — `packed_chunk` over one `PackedB` panel set per
//! batch element above `util::pack_min_mnk()` (applied to the per-element
//! m·n·k, exactly the predicate a looped call would see), the direct
//! strided chunk kernels below it. The per-element contract (init from C,
//! single adds in strictly ascending k, epilogue last) is untouched, so a
//! batched call is BITWISE identical to the equivalent loop of `gemm_nn` /
//! `gemm_tn` / `gemm_nt` calls at any thread count and on either kernel
//! path — pinned by the property tests below and relied on by the
//! attention rewiring in `backend::native` (`PALLAS_ATTN_BATCHED` is a
//! pure throughput knob).

use crate::linalg::gemm::{
    self, nn_chunk, nt_chunk, pack_b_nn, pack_b_nt, packed_chunk, par_rows, tn_chunk, use_packed,
    PackedB,
};
use crate::obs::{self, Counter, Span};
use crate::tensor::{BatchView, Tensor};
use crate::util;

/// Thread clamp on the TOTAL batched work (batch·m·n·k against the shared
/// `PALLAS_PAR_MIN` knob) — a batch of small matrices is still a big job.
fn batched_threads(batch: usize, m: usize, k: usize, n: usize, threads: usize) -> usize {
    let work = batch.saturating_mul(m).saturating_mul(n).saturating_mul(k);
    if work < util::par_min_mnk() {
        1
    } else {
        threads
    }
}

/// Pack one B panel set per batch element (parallel across elements when
/// the call is threaded — packing is pure copies, so order never matters).
/// Timed under [`Span::GemmBatchedPack`] with staged bytes counted —
/// observational only, never branches the math.
fn pack_all<F>(batch: usize, threads: usize, pack: F) -> Vec<PackedB>
where
    F: Fn(usize) -> PackedB + Sync,
{
    let packs = {
        let _pk = obs::span(Span::GemmBatchedPack);
        if threads > 1 && batch > 1 {
            gemm::parallel_map(batch, pack)
        } else {
            (0..batch).map(pack).collect()
        }
    };
    obs::add(Counter::PackBytes, packs.iter().map(|p| p.bytes()).sum());
    packs
}

/// Open the per-call batched-GEMM span and bump the path/FLOP counters.
fn batched_probe(batch: usize, m: usize, k: usize, n: usize, packed: bool) -> obs::SpanGuard {
    obs::add(
        if packed { Counter::GemmBatchedPackedCalls } else { Counter::GemmBatchedDirectCalls },
        1,
    );
    obs::add(Counter::GemmFlops, 2 * (batch * m) as u64 * k as u64 * n as u64);
    obs::span(if packed { Span::GemmBatchedPacked } else { Span::GemmBatchedDirect })
}

/// Drive `body(batch_idx, row0, row1, c_rows)` over the whole
/// (batch × row) grid: the dense C buffer is split into contiguous
/// per-thread row chunks spanning batch boundaries; each intersected batch
/// element gets one call covering its rows inside the chunk. Chunk
/// boundaries depend only on (batch·m, threads) and every output element
/// is owned by exactly one thread, so any thread count computes the same
/// bits (the chunk kernels' per-row math is grouping-invariant).
fn for_each_span<F>(c: &mut [f32], batch: usize, m: usize, n: usize, threads: usize, body: F)
where
    F: Fn(usize, usize, usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(c.len(), batch * m * n);
    par_rows(c, batch * m, n, threads, |g0, g1, rows| {
        let mut g = g0;
        let mut off = 0;
        while g < g1 {
            let bi = g / m;
            let l0 = g % m;
            let take = (m - l0).min(g1 - g);
            let len = take * n;
            body(bi, l0, l0 + take, &mut rows[off..off + len]);
            off += len;
            g += take;
        }
    });
}

fn batched_nn_impl(
    a: &BatchView<'_>,
    b: &BatchView<'_>,
    c: &mut [f32],
    acc: bool,
    threads: usize,
    packed: bool,
) {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let batch = a.batch();
    let threads = batched_threads(batch, m, k, n, threads);
    let _sp = batched_probe(batch, m, k, n, packed);
    if packed {
        let packs = pack_all(batch, threads, |i| pack_b_nn(b.slice(i), k, n, b.row_stride));
        for_each_span(c, batch, m, n, threads, |bi, l0, _l1, rows| {
            packed_chunk(rows, l0, n, a.slice(bi), a.row_stride, 1, &packs[bi], acc, None);
        });
    } else {
        for_each_span(c, batch, m, n, threads, |bi, l0, _l1, rows| {
            if !acc {
                rows.fill(0.0);
            }
            nn_chunk(rows, a.slice(bi), b.slice(bi), l0, k, n, a.row_stride, b.row_stride);
        });
    }
}

fn batched_tn_impl(
    a: &BatchView<'_>,
    b: &BatchView<'_>,
    c: &mut [f32],
    acc: bool,
    threads: usize,
    packed: bool,
) {
    // A_i is stored [k, m]: per output row the A step is 1, per k it is the
    // row stride — the microkernel's (ars, aks) addressing handles both.
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let batch = a.batch();
    let threads = batched_threads(batch, m, k, n, threads);
    let _sp = batched_probe(batch, m, k, n, packed);
    if packed {
        let packs = pack_all(batch, threads, |i| pack_b_nn(b.slice(i), k, n, b.row_stride));
        for_each_span(c, batch, m, n, threads, |bi, l0, _l1, rows| {
            packed_chunk(rows, l0, n, a.slice(bi), 1, a.row_stride, &packs[bi], acc, None);
        });
    } else {
        for_each_span(c, batch, m, n, threads, |bi, l0, _l1, rows| {
            if !acc {
                rows.fill(0.0);
            }
            tn_chunk(rows, a.slice(bi), b.slice(bi), l0, k, m, n, a.row_stride, b.row_stride);
        });
    }
}

fn batched_nt_impl(
    a: &BatchView<'_>,
    b: &BatchView<'_>,
    c: &mut [f32],
    acc: bool,
    threads: usize,
    packed: bool,
) {
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let batch = a.batch();
    let threads = batched_threads(batch, m, k, n, threads);
    let _sp = batched_probe(batch, m, k, n, packed);
    if packed {
        let packs = pack_all(batch, threads, |i| pack_b_nt(b.slice(i), n, k, b.row_stride));
        for_each_span(c, batch, m, n, threads, |bi, l0, _l1, rows| {
            packed_chunk(rows, l0, n, a.slice(bi), a.row_stride, 1, &packs[bi], acc, None);
        });
    } else {
        for_each_span(c, batch, m, n, threads, |bi, l0, _l1, rows| {
            nt_chunk(rows, a.slice(bi), b.slice(bi), l0, k, n, acc, a.row_stride, b.row_stride);
        });
    }
}

/// c ⊕= A_i·B_i per batch element; c is dense [batch, m, n]. `acc=false`
/// overwrites, `acc=true` accumulates.
pub fn gemm_batched_nn(
    a: &BatchView<'_>,
    b: &BatchView<'_>,
    c: &mut [f32],
    acc: bool,
    threads: usize,
) {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    assert_eq!(b.rows, k, "gemm_batched_nn: inner dims {k} vs {}", b.rows);
    assert_eq!(a.batch(), b.batch(), "gemm_batched_nn: batch mismatch");
    assert_eq!(c.len(), a.batch() * m * n, "gemm_batched_nn: c len");
    batched_nn_impl(a, b, c, acc, threads, use_packed(m, k, n));
}

/// c ⊕= A_iᵀ·B_i per batch element for A_i [k,m], B_i [k,n]; c is dense
/// [batch, m, n].
pub fn gemm_batched_tn(
    a: &BatchView<'_>,
    b: &BatchView<'_>,
    c: &mut [f32],
    acc: bool,
    threads: usize,
) {
    let (k, m, n) = (a.rows, a.cols, b.cols);
    assert_eq!(b.rows, k, "gemm_batched_tn: inner dims {k} vs {}", b.rows);
    assert_eq!(a.batch(), b.batch(), "gemm_batched_tn: batch mismatch");
    assert_eq!(c.len(), a.batch() * m * n, "gemm_batched_tn: c len");
    batched_tn_impl(a, b, c, acc, threads, use_packed(m, k, n));
}

/// c ⊕= A_i·B_iᵀ per batch element for A_i [m,k], B_i [n,k]; c is dense
/// [batch, m, n].
pub fn gemm_batched_nt(
    a: &BatchView<'_>,
    b: &BatchView<'_>,
    c: &mut [f32],
    acc: bool,
    threads: usize,
) {
    let (m, k, n) = (a.rows, a.cols, b.rows);
    assert_eq!(b.cols, k, "gemm_batched_nt: inner dims {k} vs {}", b.cols);
    assert_eq!(a.batch(), b.batch(), "gemm_batched_nt: batch mismatch");
    assert_eq!(c.len(), a.batch() * m * n, "gemm_batched_nt: c len");
    batched_nt_impl(a, b, c, acc, threads, use_packed(m, k, n));
}

/// C = A_i·B_i as an owned dense [batch*m, n] tensor.
pub fn matmul_batched_nn(a: &BatchView<'_>, b: &BatchView<'_>, threads: usize) -> Tensor {
    let mut c = Tensor::zeros(&[a.batch() * a.rows, b.cols]);
    gemm_batched_nn(a, b, &mut c.data, false, threads);
    c
}

/// C = A_iᵀ·B_i as an owned dense [batch*m, n] tensor.
pub fn matmul_batched_tn(a: &BatchView<'_>, b: &BatchView<'_>, threads: usize) -> Tensor {
    let mut c = Tensor::zeros(&[a.batch() * a.cols, b.cols]);
    gemm_batched_tn(a, b, &mut c.data, false, threads);
    c
}

/// C = A_i·B_iᵀ as an owned dense [batch*m, n] tensor.
pub fn matmul_batched_nt(a: &BatchView<'_>, b: &BatchView<'_>, threads: usize) -> Tensor {
    let mut c = Tensor::zeros(&[a.batch() * a.rows, b.rows]);
    gemm_batched_nt(a, b, &mut c.data, false, threads);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// A random batch of matrices embedded in one backing buffer with a
    /// random per-matrix gap and a random excess row stride — exercises
    /// every strided-addressing path at once.
    fn rand_batch(
        rng: &mut Pcg64,
        batch: usize,
        rows: usize,
        cols: usize,
    ) -> (Vec<f32>, Vec<usize>, usize) {
        let row_stride = cols + rng.below(4);
        let mat_span = (rows - 1) * row_stride + cols;
        let gap = rng.below(5);
        let mut offsets = Vec::with_capacity(batch);
        let mut end = 0usize;
        for _ in 0..batch {
            offsets.push(end);
            end += mat_span + gap;
        }
        let mut data = vec![0.0f32; end.max(mat_span)];
        rng.fill_normal(&mut data, 1.0);
        (data, offsets, row_stride)
    }

    /// Reference: loop the public single-matrix GEMMs over dense copies of
    /// each batch element (the per-head-loop shape this layer replaces).
    fn looped(
        layout: char,
        a: &BatchView<'_>,
        b: &BatchView<'_>,
        init: &[f32],
        acc: bool,
        threads: usize,
    ) -> Vec<f32> {
        let batch = a.batch();
        let (m, k, n) = match layout {
            'n' => (a.rows, a.cols, b.cols),
            't' => (a.cols, a.rows, b.cols),
            _ => (a.rows, a.cols, b.rows),
        };
        let mut c = init.to_vec();
        for i in 0..batch {
            let ad = a.to_tensor(i);
            let bd = b.to_tensor(i);
            let ci = &mut c[i * m * n..(i + 1) * m * n];
            match layout {
                'n' => gemm::gemm_nn(m, k, n, &ad.data, &bd.data, ci, acc, threads),
                't' => gemm::gemm_tn(k, m, n, &ad.data, &bd.data, ci, acc, threads),
                _ => gemm::gemm_nt(m, k, n, &ad.data, &bd.data, ci, acc, threads),
            }
        }
        c
    }

    /// THE batched contract: for every layout, accumulate mode, kernel
    /// path and thread count, a batched call over strided views produces
    /// the IDENTICAL BITS of the equivalent loop of single GEMM calls.
    #[test]
    fn batched_matches_looped_bitwise_for_all_layouts() {
        let mut rng = Pcg64::new(0xBA7C);
        for trial in 0..25 {
            let batch = 1 + rng.below(5);
            let m = 1 + rng.below(18);
            let k = 1 + rng.below(23);
            let n = 1 + rng.below(20);
            // nn/tn share B [k, n]; nt uses B [n, k]
            let (ad_nn, ao_nn, als_nn) = rand_batch(&mut rng, batch, m, k); // A [m,k]
            let (ad_tn, ao_tn, als_tn) = rand_batch(&mut rng, batch, k, m); // A [k,m]
            let (bd_nn, bo_nn, bls_nn) = rand_batch(&mut rng, batch, k, n); // B [k,n]
            let (bd_nt, bo_nt, bls_nt) = rand_batch(&mut rng, batch, n, k); // B [n,k]
            let mut init = vec![0.0f32; batch * m * n];
            rng.fill_normal(&mut init, 1.0);
            let a_nn = BatchView::from_offsets(&ad_nn, ao_nn, m, k, als_nn);
            let a_tn = BatchView::from_offsets(&ad_tn, ao_tn, k, m, als_tn);
            let b_nn = BatchView::from_offsets(&bd_nn, bo_nn, k, n, bls_nn);
            let b_nt = BatchView::from_offsets(&bd_nt, bo_nt, n, k, bls_nt);
            for acc in [false, true] {
                for &threads in &[1usize, 3] {
                    for packed in [false, true] {
                        let want = looped('n', &a_nn, &b_nn, &init, acc, 1);
                        let mut got = init.clone();
                        batched_nn_impl(&a_nn, &b_nn, &mut got, acc, threads, packed);
                        assert_eq!(got, want, "nn trial {trial} acc={acc} t={threads} p={packed}");

                        let want = looped('t', &a_tn, &b_nn, &init, acc, 1);
                        let mut got = init.clone();
                        batched_tn_impl(&a_tn, &b_nn, &mut got, acc, threads, packed);
                        assert_eq!(got, want, "tn trial {trial} acc={acc} t={threads} p={packed}");

                        let want = looped('x', &a_nn, &b_nt, &init, acc, 1);
                        let mut got = init.clone();
                        batched_nt_impl(&a_nn, &b_nt, &mut got, acc, threads, packed);
                        assert_eq!(got, want, "nt trial {trial} acc={acc} t={threads} p={packed}");
                    }
                }
            }
        }
    }

    /// Grid scheduling sanity: thread counts that split mid-matrix, one
    /// matrix per thread, and far more threads than rows all agree with the
    /// single-thread bits on both kernel paths.
    #[test]
    fn batched_is_thread_count_invariant() {
        let mut rng = Pcg64::new(7);
        let (batch, m, k, n) = (5, 7, 19, 11);
        let (ad, ao, als) = rand_batch(&mut rng, batch, m, k);
        let (bd, bo, bls) = rand_batch(&mut rng, batch, k, n);
        let a = BatchView::from_offsets(&ad, ao, m, k, als);
        let b = BatchView::from_offsets(&bd, bo, k, n, bls);
        for packed in [false, true] {
            let mut base = vec![0.0f32; batch * m * n];
            batched_nn_impl(&a, &b, &mut base, false, 1, packed);
            for threads in [2, 3, 5, 8, 64] {
                let mut c = vec![0.0f32; batch * m * n];
                batched_nn_impl(&a, &b, &mut c, false, threads, packed);
                assert_eq!(c, base, "nn differs at {threads} threads (packed={packed})");
            }
        }
    }

    /// The interleaved-heads addressing pattern (two-level (batch, head)
    /// offsets) round-trips through the batched kernels.
    #[test]
    fn batched_handles_interleaved_head_views() {
        let mut rng = Pcg64::new(11);
        let (b, t, h, dh) = (2usize, 5usize, 3usize, 4usize);
        let d = h * dh;
        let mut q = Tensor::zeros(&[b * t, d]);
        let mut kx = Tensor::zeros(&[b * t, d]);
        rng.fill_normal(&mut q.data, 1.0);
        rng.fill_normal(&mut kx.data, 1.0);
        let qv = BatchView::heads(&q, b, t, h, dh);
        let kv = BatchView::heads(&kx, b, t, h, dh);
        let s = matmul_batched_nt(&qv, &kv, 2); // [b*h*t, t]
        assert_eq!(s.shape, vec![b * h * t, t]);
        for bh in 0..b * h {
            let qh = qv.to_tensor(bh);
            let kh = kv.to_tensor(bh);
            let want = qh.matmul_nt(&kh);
            assert_eq!(
                &s.data[bh * t * t..(bh + 1) * t * t],
                &want.data[..],
                "head {bh} scores differ"
            );
        }
    }
}
