//! Numerical linear algebra substrate: the packed-panel, register-tiled,
//! multi-threaded GEMM kernel layer (`gemm`) that the tensor matmul family
//! and the native backend's hot paths run on — see gemm's module docs for
//! the two execution paths and the bitwise summation contract — its
//! batched strided sibling (`gemm_batched`: one call over a leading batch
//! dimension, the attention path's substrate), plus the randomized range
//! finder the GaLore baseline uses.
//!
//! GaLore (Zhao et al., 2024) projects each 2-D gradient G [m,n] onto a
//! rank-r subspace: with m <= n it uses the top-r left singular vectors P
//! [m,r] and optimizes Adam on Pᵀ G [r,n].  The paper uses a full SVD every
//! T steps; we use a randomized range finder (Halko et al.) with a few
//! power iterations — the same subspace class at a fraction of the cost
//! (documented substitution, DESIGN.md §6.6).

pub mod gemm;
pub mod gemm_batched;

pub use gemm::Mat;

use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Modified Gram-Schmidt orthonormalization of the columns of A [m, r]
/// in place. Columns with norm < eps are replaced with zeros.
pub fn orthonormalize_cols(a: &mut Tensor) {
    let (m, r) = (a.rows(), a.cols());
    for j in 0..r {
        // subtract projections onto previous columns (twice for stability)
        for _ in 0..2 {
            for p in 0..j {
                let mut dot = 0.0f64;
                for i in 0..m {
                    dot += (a.at(i, p) as f64) * (a.at(i, j) as f64);
                }
                for i in 0..m {
                    let v = a.at(i, j) - (dot as f32) * a.at(i, p);
                    a.set(i, j, v);
                }
            }
        }
        let mut nrm = 0.0f64;
        for i in 0..m {
            nrm += (a.at(i, j) as f64).powi(2);
        }
        let nrm = nrm.sqrt();
        if nrm < 1e-12 {
            for i in 0..m {
                a.set(i, j, 0.0);
            }
        } else {
            let inv = (1.0 / nrm) as f32;
            for i in 0..m {
                a.set(i, j, a.at(i, j) * inv);
            }
        }
    }
}

/// Randomized top-r range finder: returns P [m, r] with orthonormal columns
/// approximately spanning the top-r left singular subspace of A [m, n].
/// `power` extra power iterations sharpen the spectrum separation.
pub fn range_finder(a: &Tensor, r: usize, power: usize, rng: &mut Pcg64) -> Tensor {
    let (m, n) = (a.rows(), a.cols());
    let r = r.min(m).min(n).max(1);
    // Y = A @ Omega, Omega [n, r] gaussian
    let mut omega = Tensor::zeros(&[n, r]);
    rng.fill_normal(&mut omega.data, 1.0);
    let mut y = a.matmul(&omega); // [m, r]
    orthonormalize_cols(&mut y);
    for _ in 0..power {
        // Z = Aᵀ Y ; Y = A Z  (with re-orthonormalization)
        let mut z = a.matmul_tn(&y); // A [m,n] -> Aᵀ Y: matmul_tn(A, Y) = Aᵀ@Y [n, r]
        orthonormalize_cols(&mut z);
        y = a.matmul(&z);
        orthonormalize_cols(&mut y);
    }
    y
}

/// Spectral-ish norm estimate via a few power iterations (used by tests and
/// the perf roofline notes).
pub fn spectral_norm_est(a: &Tensor, iters: usize, rng: &mut Pcg64) -> f64 {
    let n = a.cols();
    let mut v = Tensor::zeros(&[n, 1]);
    rng.fill_normal(&mut v.data, 1.0);
    let mut sigma = 0.0f64;
    for _ in 0..iters {
        let u = a.matmul(&v); // [m,1]
        let un = u.fro_norm();
        if un < 1e-30 {
            return 0.0;
        }
        let mut w = a.matmul_tn(&u); // [n,1]
        sigma = w.fro_norm() / un;
        let wn = w.fro_norm();
        if wn > 1e-30 {
            w.scale((1.0 / wn) as f32);
        }
        v = w;
    }
    sigma
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_t(m: usize, n: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::new(seed);
        let mut t = Tensor::zeros(&[m, n]);
        rng.fill_normal(&mut t.data, 1.0);
        t
    }

    fn col_dot(a: &Tensor, j: usize, k: usize) -> f64 {
        (0..a.rows()).map(|i| (a.at(i, j) as f64) * (a.at(i, k) as f64)).sum()
    }

    #[test]
    fn orthonormalize_makes_orthonormal() {
        let mut a = rand_t(20, 5, 1);
        orthonormalize_cols(&mut a);
        for j in 0..5 {
            for k in 0..=j {
                let d = col_dot(&a, j, k);
                let want = if j == k { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-5, "col ({j},{k}) dot {d}");
            }
        }
    }

    #[test]
    fn range_finder_captures_low_rank_matrix_exactly() {
        // A = u vᵀ rank-2; range_finder(r=2) must reconstruct A via P Pᵀ A.
        let u = rand_t(16, 2, 2);
        let v = rand_t(2, 24, 3);
        let a = u.matmul(&v);
        let mut rng = Pcg64::new(4);
        let p = range_finder(&a, 2, 2, &mut rng);
        // residual A - P (Pᵀ A)
        let pta = p.matmul_tn(&a); // [2, 24]
        let approx = p.matmul(&pta);
        let mut resid = a.clone();
        resid.axpy(-1.0, &approx);
        assert!(resid.fro_norm() < 1e-4 * a.fro_norm().max(1.0), "resid {}", resid.fro_norm());
    }

    #[test]
    fn range_finder_energy_dominates_random_subspace() {
        // On a matrix with decaying spectrum, the top-r range should capture
        // more energy than a random r-subspace.
        let m = 24;
        let n = 32;
        let mut a = Tensor::zeros(&[m, n]);
        let mut rng = Pcg64::new(5);
        for r in 0..6 {
            let u = rand_t(m, 1, 100 + r as u64);
            let v = rand_t(1, n, 200 + r as u64);
            let s = 1.0 / (1 << r) as f32; // sigma: 1, .5, .25, ...
            let uv = u.matmul(&v);
            a.axpy(s, &uv);
        }
        let p = range_finder(&a, 2, 2, &mut rng);
        let energy = p.matmul_tn(&a).fro_norm();
        let mut q = rand_t(m, 2, 999);
        orthonormalize_cols(&mut q);
        let rand_energy = q.matmul_tn(&a).fro_norm();
        assert!(energy > rand_energy, "range {energy} vs random {rand_energy}");
    }

    #[test]
    fn spectral_norm_of_identityish() {
        let mut a = Tensor::zeros(&[8, 8]);
        for i in 0..8 {
            a.set(i, i, 3.0);
        }
        let mut rng = Pcg64::new(6);
        let s = spectral_norm_est(&a, 30, &mut rng);
        assert!((s - 3.0).abs() < 1e-3, "sigma {s}");
    }

    #[test]
    fn range_finder_handles_degenerate_shapes() {
        let a = rand_t(3, 100, 7);
        let mut rng = Pcg64::new(8);
        let p = range_finder(&a, 8, 1, &mut rng); // r clamped to min(m,n)=3
        assert_eq!(p.shape, vec![3, 3]);
    }
}
