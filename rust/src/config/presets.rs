//! Model preset registry — the Rust mirror of python/compile/presets.py.
//!
//! The native backend builds models from these dims directly (no manifest
//! needed); the PJRT backend cross-checks them against the manifest. The
//! parameter table produced by `param_specs` is the ABI: it must match
//! python/compile/model.py::param_specs order exactly (tok_emb, per-layer
//! [attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down], final_norm,
//! head) — do not reorder.

use crate::runtime::ParamSpec;

/// LLaMA-architecture decoder dims scaled to single-CPU-core scale.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Preset {
    pub name: &'static str,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
}

impl Preset {
    pub fn d_head(&self) -> usize {
        debug_assert_eq!(self.d_model % self.n_heads, 0);
        self.d_model / self.n_heads
    }

    /// Total LM-head parameter count (matches presets.py::param_count).
    pub fn param_count(&self) -> usize {
        let (v, d, f) = (self.vocab, self.d_model, self.d_ff);
        let per_layer = 2 * d + 4 * d * d + 3 * d * f;
        v * d + self.n_layers * per_layer + d + d * v
    }

    /// Ordered parameter table for a head ("lm" | "cls" | "reg").
    pub fn param_specs(&self, head: &str, n_out: usize) -> Vec<ParamSpec> {
        let d = self.d_model;
        let mut specs = vec![ParamSpec { name: "tok_emb".into(), shape: vec![self.vocab, d] }];
        for i in 0..self.n_layers {
            let pre = format!("layers.{i}.");
            let push = |specs: &mut Vec<ParamSpec>, suffix: &str, shape: Vec<usize>| {
                specs.push(ParamSpec { name: format!("{pre}{suffix}"), shape });
            };
            push(&mut specs, "attn_norm", vec![d]);
            push(&mut specs, "wq", vec![d, d]);
            push(&mut specs, "wk", vec![d, d]);
            push(&mut specs, "wv", vec![d, d]);
            push(&mut specs, "wo", vec![d, d]);
            push(&mut specs, "mlp_norm", vec![d]);
            push(&mut specs, "w_gate", vec![d, self.d_ff]);
            push(&mut specs, "w_up", vec![d, self.d_ff]);
            push(&mut specs, "w_down", vec![self.d_ff, d]);
        }
        specs.push(ParamSpec { name: "final_norm".into(), shape: vec![d] });
        match head {
            "lm" => specs.push(ParamSpec { name: "lm_head".into(), shape: vec![d, self.vocab] }),
            "cls" | "reg" => {
                let n = if head == "reg" { 1 } else { n_out };
                specs.push(ParamSpec { name: "cls_head".into(), shape: vec![d, n] });
                specs.push(ParamSpec { name: "cls_bias".into(), shape: vec![n] });
            }
            other => panic!("unknown head {other:?}"),
        }
        specs
    }

    /// Default LM batch shape — mirrors aot.py's DEFAULT_PLAN (8, 64).
    pub fn lm_batch(&self) -> (usize, usize) {
        (8, 64.min(self.max_seq))
    }

    /// Default classifier/regression batch shape — aot.py uses (16, 32).
    pub fn cls_batch(&self) -> (usize, usize) {
        (16, 32.min(self.max_seq))
    }
}

/// The nano..base ladder (stand-ins for the paper's LLaMA 60M..7B), plus
/// `grain`: a deliberately odd-dimensioned preset (nothing is a multiple of
/// the GEMM block/unroll sizes) that pins the blocked kernels' remainder
/// paths in tests/native_golden.rs and tests/grad_check.rs.
#[rustfmt::skip]
pub const PRESETS: [Preset; 6] = [
    Preset { name: "nano", vocab: 256, d_model: 64, n_layers: 2, n_heads: 2, d_ff: 176, max_seq: 64 },
    Preset { name: "grain", vocab: 101, d_model: 18, n_layers: 2, n_heads: 1, d_ff: 29, max_seq: 32 },
    Preset { name: "micro", vocab: 256, d_model: 128, n_layers: 4, n_heads: 4, d_ff: 352, max_seq: 64 },
    Preset { name: "tiny", vocab: 256, d_model: 256, n_layers: 6, n_heads: 4, d_ff: 688, max_seq: 64 },
    Preset { name: "small", vocab: 256, d_model: 320, n_layers: 8, n_heads: 8, d_ff: 864, max_seq: 64 },
    Preset { name: "base", vocab: 256, d_model: 448, n_layers: 10, n_heads: 8, d_ff: 1216, max_seq: 64 },
];

pub fn get(name: &str) -> Option<&'static Preset> {
    PRESETS.iter().find(|p| p.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nano_matches_manifest_numbers() {
        // the same numbers the python preset registry and the shipped
        // manifest report for nano
        let p = get("nano").unwrap();
        assert_eq!(p.d_head(), 32);
        assert_eq!(p.param_count(), 133_440);
    }

    #[test]
    fn lm_spec_table_shape_and_order() {
        let p = get("nano").unwrap();
        let specs = p.param_specs("lm", 0);
        assert_eq!(specs.len(), 1 + 9 * p.n_layers + 2);
        assert_eq!(specs[0].name, "tok_emb");
        assert_eq!(specs[1].name, "layers.0.attn_norm");
        assert_eq!(specs[9].name, "layers.0.w_down");
        assert_eq!(specs[9].shape, vec![176, 64]);
        assert_eq!(specs.last().unwrap().name, "lm_head");
        let total: usize = specs.iter().map(ParamSpec::numel).sum();
        assert_eq!(total, p.param_count());
    }

    #[test]
    fn cls_and_reg_heads() {
        let p = get("nano").unwrap();
        let cls = p.param_specs("cls", 3);
        assert_eq!(cls.last().unwrap().name, "cls_bias");
        assert_eq!(cls[cls.len() - 2].shape, vec![64, 3]);
        let reg = p.param_specs("reg", 1);
        assert_eq!(reg[reg.len() - 2].shape, vec![64, 1]);
    }

    #[test]
    fn every_preset_is_consistent() {
        for p in &PRESETS {
            assert_eq!(p.d_model % p.n_heads, 0, "{}", p.name);
            let total: usize = p.param_specs("lm", 0).iter().map(ParamSpec::numel).sum();
            assert_eq!(total, p.param_count(), "{}", p.name);
        }
        assert!(get("nope").is_none());
    }
}
