//! Experiment configuration: typed configs, method registry, presets.
//!
//! Every run is fully described by a `TrainConfig`; experiment harnesses
//! (rust/src/experiments/) construct these programmatically and the CLI can
//! override any field with `--key value` pairs. Configs serialize to JSON in
//! the run's results directory so every number in EXPERIMENTS.md is
//! reproducible.

pub mod presets;

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Which execution engine runs the model fwd/bwd (the L2.5 backend layer,
/// rust/src/backend/). `Auto` prefers PJRT when AOT artifacts are present
/// and falls back to the pure-Rust native engine otherwise.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Auto,
    Native,
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        Ok(match s {
            "auto" => BackendKind::Auto,
            "native" => BackendKind::Native,
            "pjrt" | "xla" => BackendKind::Pjrt,
            _ => bail!("unknown backend {s:?} (want auto|native|pjrt)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Auto => "auto",
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Which optimization method drives the run (the paper's comparison set).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// BlockLLM (the paper's contribution): greedy block selection by
    /// processed-gradient norm / visit frequency + masked sparse Adam.
    BlockLlm,
    /// Ablation: select layers with the SMALLEST gradient norms (§3.3).
    BlockLlmSubOpt,
    /// Ablation: BlockLLM without the visit-frequency term f_l (§3.3).
    BlockLlmNoFreq,
    /// Full-parameter Adam (the FFT baseline in Tables 7/8).
    FullAdam,
    /// GaLore: gradient low-rank projection + Adam in rank-r space.
    GaLore,
    /// LoRA: rank-r adapters per 2-D matrix, Adam over adapters only.
    LoRa,
    /// BAdam (Luo et al., 2024): cyclic block coordinate Adam, K steps/block.
    BAdam,
    /// Magnitude-pruning analysis optimizer (§2): update top-k |W| coords.
    Magnitude,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "blockllm" => Method::BlockLlm,
            "blockllm-subopt" => Method::BlockLlmSubOpt,
            "blockllm-nofreq" => Method::BlockLlmNoFreq,
            "adam" | "fft" => Method::FullAdam,
            "galore" => Method::GaLore,
            "lora" => Method::LoRa,
            "badam" => Method::BAdam,
            "magnitude" => Method::Magnitude,
            _ => bail!("unknown method {s:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::BlockLlm => "blockllm",
            Method::BlockLlmSubOpt => "blockllm-subopt",
            Method::BlockLlmNoFreq => "blockllm-nofreq",
            Method::FullAdam => "adam",
            Method::GaLore => "galore",
            Method::LoRa => "lora",
            Method::BAdam => "badam",
            Method::Magnitude => "magnitude",
        }
    }
}

/// How layer gradient norms are computed for selection (DESIGN.md §6.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NormKind {
    /// Frobenius norm (size-biased: big layers win).
    Fro,
    /// Root-mean-square norm (size-invariant; default).
    Rms,
}

/// What happens to a deselected layer's Adam state (paper §2.2 "Memory
/// Efficiency": reset is the paper's choice; offload-to-CPU was tried and
/// rejected — both are implemented so the finding can be reproduced).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StatePolicy {
    /// drop state on re-selection (the paper's final design)
    Reset,
    /// stash (M, V) on the host and restore when the layer is re-selected
    Offload,
}

/// Masking policy within selected layers (DESIGN.md §6.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MaskMode {
    /// Alg. 2 literal: per-layer (1-zeta)-percentile threshold on |G̃|.
    Alg2,
    /// Only the last (overshooting) layer is masked; earlier layers dense.
    OvershootOnly,
    /// No intra-layer masking at all (whole selected layers train densely).
    DenseLayers,
}

/// Workload selector — maps to a data generator + artifact kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// C4-sim LM pretraining stream.
    C4Pretrain,
    /// Alpaca-sim instruction finetuning (LM with masked prefix loss).
    AlpacaFinetune,
    /// GLUE-sim classification task (id 0..7) on a pretrained trunk.
    Glue(usize),
    /// The §2 analysis protocol: sentiment-ish task A -> acceptability-ish B.
    DomainShift,
}

impl Task {
    pub fn name(&self) -> String {
        match self {
            Task::C4Pretrain => "c4-pretrain".into(),
            Task::AlpacaFinetune => "alpaca-finetune".into(),
            Task::Glue(i) => format!("glue-{}", crate::data::gluesim::TASK_NAMES[*i]),
            Task::DomainShift => "domain-shift".into(),
        }
    }
}

/// The full run description.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub preset: String,
    pub task: Task,
    pub method: Method,
    /// execution backend: auto (pjrt when artifacts exist, else native),
    /// native (pure Rust), pjrt (require artifacts)
    pub backend: BackendKind,
    pub steps: usize,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub seed: u64,
    pub use_pallas_artifact: bool,
    /// microbatches accumulated per optimizer step (paper App. A.6/A.7
    /// train with accumulation 2-4)
    pub grad_accum: usize,

    // optimizer
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    pub cosine_lr: bool,
    pub warmup_frac: f64,

    // BlockLLM hyperparameters (paper notation)
    pub sparsity: f64,      // s: fraction NOT updated
    pub patience: usize,    // m: loss-history window
    pub sample_layers: usize, // p: extra layers scored per step
    pub norm_kind: NormKind,
    pub mask_mode: MaskMode,
    pub state_policy: StatePolicy,

    // GaLore / LoRA
    pub rank: usize,
    pub galore_scale: f64,
    pub galore_refresh: usize,
    pub lora_alpha: f64,

    // BAdam
    pub badam_k: usize, // steps per block before switching

    // Magnitude analysis (§2)
    pub mag_update_every: usize, // m in §2.1: re-select every m steps
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            preset: "nano".into(),
            task: Task::C4Pretrain,
            method: Method::BlockLlm,
            backend: BackendKind::Auto,
            steps: 200,
            eval_every: 50,
            eval_batches: 8,
            seed: 42,
            use_pallas_artifact: false,
            grad_accum: 1,
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            cosine_lr: true,
            warmup_frac: 0.0,
            sparsity: 0.5,
            patience: 50,
            sample_layers: 2,
            norm_kind: NormKind::Rms,
            mask_mode: MaskMode::Alg2,
            state_policy: StatePolicy::Reset,
            rank: 8,
            galore_scale: 0.25,
            galore_refresh: 200,
            lora_alpha: 32.0,
            badam_k: 100,
            mag_update_every: 0,
        }
    }
}

impl TrainConfig {
    /// Apply a `--key value` CLI override. Returns error on unknown keys so
    /// typos fail loudly.
    pub fn set(&mut self, key: &str, val: &str) -> Result<()> {
        match key {
            "preset" => self.preset = val.into(),
            "method" => self.method = Method::parse(val)?,
            "backend" => self.backend = BackendKind::parse(val)?,
            "task" => {
                self.task = match val {
                    "c4" | "pretrain" => Task::C4Pretrain,
                    "alpaca" | "finetune" => Task::AlpacaFinetune,
                    "domain-shift" => Task::DomainShift,
                    v if v.starts_with("glue-") => {
                        let name = &v[5..];
                        let idx = crate::data::gluesim::TASK_NAMES
                            .iter()
                            .position(|t| *t == name)
                            .ok_or_else(|| anyhow::anyhow!("unknown glue task {name}"))?;
                        Task::Glue(idx)
                    }
                    v => bail!("unknown task {v:?}"),
                }
            }
            "steps" => self.steps = val.parse()?,
            "eval-every" => self.eval_every = val.parse()?,
            "eval-batches" => self.eval_batches = val.parse()?,
            "seed" => self.seed = val.parse()?,
            "pallas" => self.use_pallas_artifact = val.parse()?,
            "grad-accum" => self.grad_accum = val.parse::<usize>()?.max(1),
            "lr" => self.lr = val.parse()?,
            "beta1" => self.beta1 = val.parse()?,
            "beta2" => self.beta2 = val.parse()?,
            "eps" => self.eps = val.parse()?,
            "weight-decay" => self.weight_decay = val.parse()?,
            "cosine-lr" => self.cosine_lr = val.parse()?,
            "warmup-frac" => self.warmup_frac = val.parse()?,
            "sparsity" | "s" => self.sparsity = val.parse()?,
            "patience" | "m" => self.patience = val.parse()?,
            "sample-layers" | "p" => self.sample_layers = val.parse()?,
            "norm" => {
                self.norm_kind = match val {
                    "fro" => NormKind::Fro,
                    "rms" => NormKind::Rms,
                    v => bail!("unknown norm {v:?}"),
                }
            }
            "state-policy" => {
                self.state_policy = match val {
                    "reset" => StatePolicy::Reset,
                    "offload" => StatePolicy::Offload,
                    v => bail!("unknown state policy {v:?}"),
                }
            }
            "mask-mode" => {
                self.mask_mode = match val {
                    "alg2" => MaskMode::Alg2,
                    "overshoot-only" => MaskMode::OvershootOnly,
                    "dense-layers" => MaskMode::DenseLayers,
                    v => bail!("unknown mask mode {v:?}"),
                }
            }
            "rank" => self.rank = val.parse()?,
            "galore-scale" => self.galore_scale = val.parse()?,
            "galore-refresh" => self.galore_refresh = val.parse()?,
            "lora-alpha" => self.lora_alpha = val.parse()?,
            "badam-k" => self.badam_k = val.parse()?,
            "mag-update-every" => self.mag_update_every = val.parse()?,
            _ => bail!("unknown config key {key:?}"),
        }
        Ok(())
    }

    /// The task value `set("task", ...)` accepts (distinct from
    /// [`Task::name`], which is the results-directory label).
    pub fn task_key(&self) -> String {
        match self.task {
            Task::C4Pretrain => "c4".into(),
            Task::AlpacaFinetune => "alpaca".into(),
            Task::Glue(i) => format!("glue-{}", crate::data::gluesim::TASK_NAMES[i]),
            Task::DomainShift => "domain-shift".into(),
        }
    }

    /// EVERY field as `set()`-compatible `(key, value)` pairs — the
    /// checkpoint round-trip: `to_kv` at suspend, replay through `set` on a
    /// default config at resume. f64 values use Rust's shortest round-trip
    /// formatting, so the rebuilt config is bit-identical.
    pub fn to_kv(&self) -> Vec<(String, String)> {
        let norm = match self.norm_kind {
            NormKind::Fro => "fro",
            NormKind::Rms => "rms",
        };
        let policy = match self.state_policy {
            StatePolicy::Reset => "reset",
            StatePolicy::Offload => "offload",
        };
        let mask = match self.mask_mode {
            MaskMode::Alg2 => "alg2",
            MaskMode::OvershootOnly => "overshoot-only",
            MaskMode::DenseLayers => "dense-layers",
        };
        vec![
            ("preset".into(), self.preset.clone()),
            ("task".into(), self.task_key()),
            ("method".into(), self.method.name().into()),
            ("backend".into(), self.backend.name().into()),
            ("steps".into(), self.steps.to_string()),
            ("eval-every".into(), self.eval_every.to_string()),
            ("eval-batches".into(), self.eval_batches.to_string()),
            ("seed".into(), self.seed.to_string()),
            ("pallas".into(), self.use_pallas_artifact.to_string()),
            ("grad-accum".into(), self.grad_accum.to_string()),
            ("lr".into(), self.lr.to_string()),
            ("beta1".into(), self.beta1.to_string()),
            ("beta2".into(), self.beta2.to_string()),
            ("eps".into(), self.eps.to_string()),
            ("weight-decay".into(), self.weight_decay.to_string()),
            ("cosine-lr".into(), self.cosine_lr.to_string()),
            ("warmup-frac".into(), self.warmup_frac.to_string()),
            ("sparsity".into(), self.sparsity.to_string()),
            ("patience".into(), self.patience.to_string()),
            ("sample-layers".into(), self.sample_layers.to_string()),
            ("norm".into(), norm.into()),
            ("state-policy".into(), policy.into()),
            ("mask-mode".into(), mask.into()),
            ("rank".into(), self.rank.to_string()),
            ("galore-scale".into(), self.galore_scale.to_string()),
            ("galore-refresh".into(), self.galore_refresh.to_string()),
            ("lora-alpha".into(), self.lora_alpha.to_string()),
            ("badam-k".into(), self.badam_k.to_string()),
            ("mag-update-every".into(), self.mag_update_every.to_string()),
        ]
    }

    /// Rebuild a config from `to_kv` output (checkpoint resume path).
    pub fn from_kv(pairs: &[(String, String)]) -> Result<TrainConfig> {
        let mut cfg = TrainConfig::default();
        for (k, v) in pairs {
            cfg.set(k, v)?;
        }
        Ok(cfg)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("preset", Json::str(self.preset.clone())),
            ("task", Json::str(self.task.name())),
            ("method", Json::str(self.method.name())),
            ("backend", Json::str(self.backend.name())),
            ("steps", Json::num(self.steps as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("lr", Json::num(self.lr)),
            ("sparsity", Json::num(self.sparsity)),
            ("patience", Json::num(self.patience as f64)),
            ("rank", Json::num(self.rank as f64)),
            ("badam_k", Json::num(self.badam_k as f64)),
            ("cosine_lr", Json::Bool(self.cosine_lr)),
            ("pallas", Json::Bool(self.use_pallas_artifact)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in [
            Method::BlockLlm,
            Method::BlockLlmSubOpt,
            Method::BlockLlmNoFreq,
            Method::FullAdam,
            Method::GaLore,
            Method::LoRa,
            Method::BAdam,
            Method::Magnitude,
        ] {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("nope").is_err());
    }

    #[test]
    fn backend_parse_roundtrip() {
        for b in [BackendKind::Auto, BackendKind::Native, BackendKind::Pjrt] {
            assert_eq!(BackendKind::parse(b.name()).unwrap(), b);
        }
        assert!(BackendKind::parse("gpu").is_err());
    }

    #[test]
    fn config_overrides() {
        let mut c = TrainConfig::default();
        c.set("method", "galore").unwrap();
        c.set("sparsity", "0.95").unwrap();
        c.set("m", "100").unwrap();
        c.set("task", "glue-cola").unwrap();
        c.set("backend", "native").unwrap();
        assert_eq!(c.backend, BackendKind::Native);
        assert!(c.set("backend", "tpu").is_err());
        assert_eq!(c.method, Method::GaLore);
        assert_eq!(c.sparsity, 0.95);
        assert_eq!(c.patience, 100);
        assert!(matches!(c.task, Task::Glue(_)));
        assert!(c.set("not-a-key", "1").is_err());
        assert!(c.set("steps", "abc").is_err());
    }

    #[test]
    fn kv_roundtrip_rebuilds_every_field() {
        let mut c = TrainConfig::default();
        c.set("method", "magnitude").unwrap();
        c.set("task", "glue-stsb").unwrap();
        c.set("backend", "native").unwrap();
        c.set("lr", "0.0007").unwrap();
        c.set("sparsity", "0.95").unwrap();
        c.set("state-policy", "offload").unwrap();
        c.set("mask-mode", "overshoot-only").unwrap();
        c.set("norm", "fro").unwrap();
        c.set("grad-accum", "4").unwrap();
        c.set("warmup-frac", "0.1").unwrap();
        let r = TrainConfig::from_kv(&c.to_kv()).unwrap();
        assert_eq!(format!("{c:?}"), format!("{r:?}"));
        assert_eq!(c.lr.to_bits(), r.lr.to_bits());
        // the default round-trips too
        let d = TrainConfig::default();
        assert_eq!(format!("{d:?}"), format!("{:?}", TrainConfig::from_kv(&d.to_kv()).unwrap()));
    }

    #[test]
    fn config_json_has_method() {
        let c = TrainConfig::default();
        let j = c.to_json();
        assert_eq!(j.req("method").unwrap().as_str().unwrap(), "blockllm");
    }
}
