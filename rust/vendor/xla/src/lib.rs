//! Offline stub of the `xla` (xla_extension) PJRT binding.
//!
//! The build container has no XLA shared library and no crates.io access, so
//! this path dependency keeps the crate compiling everywhere. `Literal` is a
//! REAL host-side implementation (shape + typed buffer) because the
//! coordinator marshals through it; the PJRT client/compile/execute surface
//! is stubbed to return errors at runtime. `Runtime::open` therefore fails
//! cleanly on a machine without the real binding, and the L2.5 backend layer
//! (rust/src/backend/) falls back to the pure-Rust `NativeBackend`.
//!
//! To run the AOT HLO artifacts for real, replace this path dependency in
//! rust/Cargo.toml with the upstream `xla` crate (xla_extension 0.5.1) — the
//! API subset below matches it exactly.

use std::fmt;

/// Stub error: a plain message (the real binding wraps XLA statuses).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const STUB: &str = "xla stub: PJRT is unavailable in this build (vendored rust/vendor/xla); \
use the native backend or link the real xla_extension binding";

fn stub_err() -> Error {
    Error(STUB.to_string())
}

// ---------------------------------------------------------------------------
// Literal: functional host implementation
// ---------------------------------------------------------------------------

/// Typed host buffer payload.
#[derive(Debug, Clone)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Element types the coordinator marshals (f32 weights/grads, i32 tokens).
pub trait NativeType: Copy + 'static {
    fn wrap(data: Vec<Self>) -> Data;
    fn unwrap(data: &Data) -> Option<&[Self]>;
    fn unwrap_mut(data: &mut Data) -> Option<&mut [Self]>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Data {
        Data::F32(data)
    }
    fn unwrap(data: &Data) -> Option<&[f32]> {
        match data {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
    fn unwrap_mut(data: &mut Data) -> Option<&mut [f32]> {
        match data {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Data {
        Data::I32(data)
    }
    fn unwrap(data: &Data) -> Option<&[i32]> {
        match data {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
    fn unwrap_mut(data: &mut Data) -> Option<&mut [i32]> {
        match data {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Host literal: shape (i64 dims, XLA convention) + typed buffer.
#[derive(Debug, Clone)]
pub struct Literal {
    pub dims: Vec<i64>,
    pub data: Data,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    /// Same buffer, new shape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape {:?} wants {} elements, literal has {}",
                dims,
                n,
                self.element_count()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Overwrite the payload in place from a host slice (same length/type).
    pub fn copy_raw_from<T: NativeType>(&mut self, src: &[T]) -> Result<()> {
        let dst = T::unwrap_mut(&mut self.data)
            .ok_or_else(|| Error("copy_raw_from: element type mismatch".into()))?;
        if dst.len() != src.len() {
            return Err(Error(format!(
                "copy_raw_from: length mismatch {} vs {}",
                dst.len(),
                src.len()
            )));
        }
        dst.copy_from_slice(src);
        Ok(())
    }

    /// Copy the payload out into a host slice (same length/type).
    pub fn copy_raw_to<T: NativeType>(&self, dst: &mut [T]) -> Result<()> {
        let src = T::unwrap(&self.data)
            .ok_or_else(|| Error("copy_raw_to: element type mismatch".into()))?;
        if dst.len() != src.len() {
            return Err(Error(format!(
                "copy_raw_to: length mismatch {} vs {}",
                src.len(),
                dst.len()
            )));
        }
        dst.copy_from_slice(src);
        Ok(())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .map(<[T]>::to_vec)
            .ok_or_else(|| Error("to_vec: element type mismatch".into()))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::unwrap(&self.data)
            .and_then(|s| s.first().copied())
            .ok_or_else(|| Error("get_first_element: empty or type mismatch".into()))
    }

    /// Flatten a tuple literal. The stub never produces tuples (they only
    /// come back from PJRT execution), so this is unreachable in practice.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error("to_tuple: stub literals are never tuples".into()))
    }
}

// ---------------------------------------------------------------------------
// PJRT surface: stubbed
// ---------------------------------------------------------------------------

/// Stub PJRT client — `cpu()` always fails, so anything holding a client is
/// unreachable at runtime in stub builds.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(stub_err())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err())
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(stub_err())
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err())
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let mut l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let l2 = l.reshape(&[2, 2]).unwrap();
        assert_eq!(l2.dims, vec![2, 2]);
        assert!(l.reshape(&[3, 3]).is_err());
        l.copy_raw_from::<f32>(&[5.0, 6.0, 7.0, 8.0]).unwrap();
        let mut out = [0.0f32; 4];
        l.copy_raw_to::<f32>(&mut out).unwrap();
        assert_eq!(out, [5.0, 6.0, 7.0, 8.0]);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 5.0);
        assert_eq!(l.to_vec::<f32>().unwrap().len(), 4);
    }

    #[test]
    fn literal_type_mismatch_rejected() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.to_vec::<f32>().is_err());
        assert!(l.get_first_element::<f32>().is_err());
        let mut buf = [0.0f32; 3];
        assert!(l.copy_raw_to::<f32>(&mut buf).is_err());
    }

    #[test]
    fn pjrt_surface_is_stubbed() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("stub"));
        assert!(HloModuleProto::from_text_file("/nonexistent").is_err());
    }
}
