//! Offline drop-in subset of the `anyhow` error-handling API.
//!
//! The build container for this repo has no crates.io access (DESIGN.md §8:
//! the offline crate universe), so the subset of `anyhow` this codebase
//! actually uses is vendored here: `Error` with a context chain, `Result`,
//! the `anyhow!`/`bail!` macros, and the `Context` extension trait for
//! `Result`/`Option`. Semantics mirror upstream: `{e}` prints the outermost
//! message, `{e:#}` prints the whole chain joined with `": "`.

use std::fmt;

/// Error: an owned message plus an optional chain of underlying causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message (upstream `Error::context`).
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: c.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the chain outermost-first.
    pub fn chain(&self) -> impl Iterator<Item = &Error> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur)
        })
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — full chain, outermost first, ": "-joined (as upstream)
            let mut first = true;
            for e in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{}", e.msg)?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut causes = self.chain().skip(1).peekable();
        if causes.peek().is_some() {
            write!(f, "\n\nCaused by:")?;
            for e in causes {
                write!(f, "\n    {}", e.msg)?;
            }
        }
        Ok(())
    }
}

/// Any std error converts into `Error`, preserving its `source()` chain as
/// context frames. (`Error` itself deliberately does not implement
/// `std::error::Error`, exactly as upstream, so this blanket impl cannot
/// collide with the identity `From<Error> for Error`.)
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in frames.into_iter().rev() {
            err = Some(Error { msg, source: err.map(Box::new) });
        }
        err.expect("at least one frame")
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("fmt", args...)` — build an `Error` from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("fmt", args...)` — early-return `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<i32> {
            let n: i32 = "notanint".parse()?;
            Ok(n)
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading config: missing");
        let o: Option<i32> = None;
        assert_eq!(format!("{}", o.with_context(|| "empty").unwrap_err()), "empty");
    }

    #[test]
    fn bail_and_anyhow_macros() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative: -1");
        let e = anyhow!("plain {}", 7);
        assert_eq!(format!("{e}"), "plain 7");
    }
}
