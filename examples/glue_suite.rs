//! Tables 7 & 8 driver — the GLUE-sim suite: BlockLLM vs GaLore r8/r4 vs
//! full finetuning across eight tasks, reporting score and peak memory.
//!
//!     cargo run --release --example glue_suite            # all 8 tasks
//!     cargo run --release --example glue_suite -- --quick # cola + sst2

use anyhow::Result;

fn main() -> Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    blockllm::experiments::run("table7", quick)
}
