//! Fig. 7 driver — selection-criterion ablations: BlockLLM vs
//! BlockLLM-SubOPT (smallest-gradient selection) and vs the
//! no-visit-frequency variant.
//!
//!     cargo run --release --example ablation_selection [-- --quick]

use anyhow::Result;

fn main() -> Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    blockllm::experiments::run("fig7", quick)
}
