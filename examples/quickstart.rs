//! Quickstart: the smallest end-to-end BlockLLM run.
//!
//! Loads the nano AOT artifact (the PALLAS-attention variant, proving the
//! L1 kernel is live in the served HLO), pretrains on the C4-sim stream for
//! 40 steps with BlockLLM (s=0.9), and prints the loss curve, block
//! selections, and the memory ledger vs full Adam.
//!
//!     cargo run --release --example quickstart

use anyhow::Result;

use blockllm::config::{Method, Task, TrainConfig};
use blockllm::experiments::common::{run_config, sparkline};
use blockllm::runtime::Runtime;
use blockllm::util::human_bytes;

fn main() -> Result<()> {
    let mut rt = Runtime::open_default()?;
    println!("PJRT up; {} artifacts in manifest", rt.manifest.artifacts.len());

    let mut cfg = TrainConfig::default();
    cfg.preset = "nano".into();
    cfg.task = Task::C4Pretrain;
    cfg.method = Method::BlockLlm;
    cfg.use_pallas_artifact = true; // L1 Pallas attention inside the HLO
    cfg.steps = 40;
    cfg.eval_every = 20;
    cfg.eval_batches = 2;
    cfg.sparsity = 0.9;
    cfg.patience = 10;
    cfg.lr = 3e-3;

    println!(
        "training {} ({} params) with BlockLLM s={} on C4-sim ...",
        cfg.preset, rt.manifest.presets[&cfg.preset].param_count, cfg.sparsity
    );
    let res = run_config(&mut rt, &cfg, None)?;

    println!("\nloss curve  {}", sparkline(&res.train_losses, 50));
    println!(
        "first {:.3} -> last {:.3}; eval ppl {:.1} (uniform would be 256)",
        res.train_losses[0],
        res.final_train_loss,
        res.final_metric()
    );
    println!(
        "peak modeled training memory: {} (full Adam would be {})",
        human_bytes(res.peak_mem_bytes),
        human_bytes(4 * 4 * rt.manifest.presets[&cfg.preset].param_count as u64),
    );
    for (k, v) in &res.telemetry {
        println!("  {k} = {v}");
    }
    println!("\nnext: cargo run --release --example pretrain_c4_sim   (Table 1)");
    println!("      ./target/release/blockllm exp --all --quick      (every table/figure)");
    Ok(())
}
