//! Quickstart: the smallest end-to-end BlockLLM run.
//!
//! Pretrains the nano model on the C4-sim stream for 40 steps with BlockLLM
//! (s=0.9) and prints the loss curve, block selections, and the memory
//! ledger vs full Adam. Runs on ANY machine: with AOT artifacts present it
//! executes via PJRT (the Pallas-attention artifact, proving the L1 kernel
//! is live in the served HLO); without them it runs the pure-Rust native
//! backend.
//!
//!     cargo run --release --example quickstart

use anyhow::Result;

use blockllm::config::{presets, Method, Task, TrainConfig};
use blockllm::experiments::common::{run_config, sparkline};
use blockllm::util::human_bytes;

fn main() -> Result<()> {
    let mut cfg = TrainConfig::default();
    cfg.preset = "nano".into();
    cfg.task = Task::C4Pretrain;
    cfg.method = Method::BlockLlm;
    cfg.use_pallas_artifact = true; // L1 Pallas attention inside the HLO (pjrt path)
    cfg.steps = 40;
    cfg.eval_every = 20;
    cfg.eval_batches = 2;
    cfg.sparsity = 0.9;
    cfg.patience = 10;
    cfg.lr = 3e-3;

    let preset = presets::get(&cfg.preset).expect("nano preset");
    println!(
        "training {} ({} params) with BlockLLM s={} on C4-sim ...",
        cfg.preset,
        preset.param_count(),
        cfg.sparsity
    );
    let res = run_config(&cfg, None)?;
    println!("execution backend: {}", res.backend);

    println!("\nloss curve  {}", sparkline(&res.train_losses, 50));
    println!(
        "first {:.3} -> last {:.3}; eval ppl {:.1} (uniform would be 256)",
        res.train_losses[0],
        res.final_train_loss,
        res.final_metric()
    );
    println!(
        "peak modeled training memory: {} (full Adam weights+grads+moments would be {})",
        human_bytes(res.peak_mem_bytes),
        human_bytes(4 * 4 * preset.param_count() as u64),
    );
    for (k, v) in &res.telemetry {
        println!("  {k} = {v}");
    }
    println!("\nnext: cargo run --release --example pretrain_c4_sim   (Table 1)");
    println!("      ./target/release/blockllm exp --all --quick      (every table/figure)");
    Ok(())
}
