//! Table 1 / Fig. 6 driver — the paper's pretraining evaluation, and this
//! repo's END-TO-END VALIDATION run (EXPERIMENTS.md §E2E): pretrain the
//! model ladder on the C4-sim corpus with BlockLLM vs GaLore, logging loss
//! curves, perplexity, and the memory ledger.
//!
//!     cargo run --release --example pretrain_c4_sim            # full ladder
//!     cargo run --release --example pretrain_c4_sim -- --quick # small ladder

use anyhow::Result;

fn main() -> Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    blockllm::experiments::run("table1", quick)?;
    blockllm::experiments::run("fig6", quick)?;
    Ok(())
}
