//! Fig. 1 / Fig. 5 driver — the paper's headline finetuning comparison:
//! BlockLLM vs LoRA vs BAdam vs GaLore on the Alpaca-sim instruction task,
//! warm-started from a C4-sim pretrained checkpoint.
//!
//!     cargo run --release --example finetune_alpaca_sim            # tiny preset
//!     cargo run --release --example finetune_alpaca_sim -- --quick # micro preset

use anyhow::Result;

fn main() -> Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    blockllm::experiments::run("fig5", quick)
}
